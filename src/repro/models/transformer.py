"""Decoder-only LM trunk (dense / MoE / MLA / VLM backbones).

Layers are stacked along a leading axis and executed under ``lax.scan``
(HLO stays small at 64 layers). MoE models with ``first_dense_layers``
unroll the dense prefix and scan the homogeneous MoE stack.

Three entry points per model:
  ``lm_forward``  — full causal forward (training), returns (logits, aux)
  ``lm_prefill``  — causal forward + populated KV cache, last-token logits
  ``lm_decode``   — one-token step against the cache
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (Params, embed_init, init_rmsnorm,
                                 mrope_cos_sin, rmsnorm, rope_cos_sin,
                                 stack_init)
from repro.models.mlp import ffn, init_ffn
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# init


def _init_block(cfg: ModelConfig, key, moe: bool, dtype):
    k1, k2 = jax.random.split(key)
    init_attn = attn.init_mla if cfg.attention_type == "mla" else attn.init_gqa
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attn(cfg, k1, dtype),
        "ffn_norm": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_moe(cfg, k2, dtype) if moe else init_ffn(cfg, k2, dtype=dtype),
    }


def init_lm(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    n_prefix = cfg.first_dense_layers if cfg.has_moe else 0
    n_stack = cfg.num_layers - n_prefix
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "layers": stack_init(
            ks[1], n_stack,
            lambda k: _init_block(cfg, k, moe=cfg.has_moe, dtype=dtype)),
    }
    if n_prefix:
        pk = jax.random.split(ks[2], n_prefix)
        p["prefix_layers"] = [
            _init_block(cfg, k, moe=False, dtype=dtype) for k in pk]
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# positions / rope tables


def _cos_sin(cfg: ModelConfig, positions: jnp.ndarray):
    """positions: (..., S) ints or (..., S, 3) M-RoPE triplets."""
    hd = cfg.head_dim if cfg.attention_type != "mla" else cfg.qk_rope_head_dim
    if cfg.mrope_sections:
        if positions.ndim >= 2 and positions.shape[-1] == 3:
            return mrope_cos_sin(positions, cfg.mrope_sections, cfg.rope_theta)
        # text-only positions: t == h == w
        trip = jnp.stack([positions] * 3, axis=-1)
        return mrope_cos_sin(trip, cfg.mrope_sections, cfg.rope_theta)
    return rope_cos_sin(positions, hd, cfg.rope_theta)


def _block_train(cfg: ModelConfig, moe: bool, q_chunk: int, moe_cf=1.25):
    def body(lp, h, cos, sin):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        if cfg.attention_type == "mla":
            h = h + attn.mla_full(lp["attn"], cfg, x, cos, sin, q_chunk=q_chunk)
        else:
            h = h + attn.gqa_full(lp["attn"], cfg, x, cos, sin, q_chunk=q_chunk)
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if moe:
            y, aux = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
            return h + y, aux
        return h + ffn(lp["ffn"], cfg, x), jnp.zeros((), jnp.float32)
    return body


# ---------------------------------------------------------------------------
# forward (train)


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    h = params["embed"][tokens].astype(_adtype(cfg))
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    return h


def _adtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def unembed(params: Params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32).T
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def lm_forward(
    params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
    positions: Optional[jnp.ndarray] = None,
    extra_embeds: Optional[jnp.ndarray] = None,
    q_chunk: int = 512, remat: bool = True, moe_cf=1.25,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full causal forward. Returns (logits (B,S,V), moe aux loss);
    ``return_hidden`` skips the unembedding (chunked-CE training path)."""
    h = embed_tokens(params, cfg, tokens, extra_embeds)
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = _cos_sin(cfg, positions)
    aux_total = jnp.zeros((), jnp.float32)

    body = _block_train(cfg, moe=False, q_chunk=q_chunk)
    for lp in params.get("prefix_layers", []):
        h, _ = body(lp, h, cos, sin)

    moe_body = _block_train(cfg, moe=cfg.has_moe, q_chunk=q_chunk, moe_cf=moe_cf)

    def scan_body(carry, lp):
        h, aux = carry
        h, a = moe_body(lp, h, cos, sin)
        return (h, aux + a), None

    if remat:
        scan_body = jax.checkpoint(scan_body)
    (h, aux_total), _ = jax.lax.scan(scan_body, (h, aux_total), params["layers"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, aux_total
    return unembed(params, cfg, h), aux_total


# ---------------------------------------------------------------------------
# prefill


def lm_prefill(
    params: Params, cfg: ModelConfig, tokens: jnp.ndarray, cache_len: int, *,
    positions: Optional[jnp.ndarray] = None,
    extra_embeds: Optional[jnp.ndarray] = None,
    q_chunk: int = 512, moe_cf=1.25,
) -> Tuple[jnp.ndarray, Params]:
    """Returns (last-token logits (B,V), stacked cache)."""
    h = embed_tokens(params, cfg, tokens, extra_embeds)
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = _cos_sin(cfg, positions)
    eff_len = cache_len if cfg.sliding_window is None else cfg.sliding_window

    def block_prefill(lp, h):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        if cfg.attention_type == "mla":
            o, cache = attn.mla_prefill(lp["attn"], cfg, x, cos, sin, eff_len,
                                        q_chunk=q_chunk)
        else:
            o, cache = attn.gqa_prefill(lp["attn"], cfg, x, cos, sin, eff_len,
                                        q_chunk=q_chunk)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if cfg.has_moe and "router" in lp["ffn"]:
            y, _ = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
        else:
            y = ffn(lp["ffn"], cfg, x)
        return h + y, cache

    prefix_caches = []
    for lp in params.get("prefix_layers", []):
        h, c = block_prefill(lp, h)
        prefix_caches.append(c)

    def scan_body(h, lp):
        h, cache = block_prefill(lp, h)
        return h, cache

    h, stack_cache = jax.lax.scan(scan_body, h, params["layers"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params, cfg, h[:, -1])
    cache = {"stack": stack_cache}
    if prefix_caches:
        cache["prefix"] = prefix_caches
    return logits, cache


# ---------------------------------------------------------------------------
# decode


def lm_decode(
    params: Params, cfg: ModelConfig, token: jnp.ndarray, cache: Params,
    pos, *, positions: Optional[jnp.ndarray] = None, moe_cf=None,
) -> Tuple[jnp.ndarray, Params]:
    """One-token step. token: (B, 1) int32; pos: scalar int32 global index.
    Returns (logits (B, V), new cache)."""
    h = params["embed"][token].astype(_adtype(cfg))
    B = h.shape[0]
    if positions is None:
        p_ = jnp.asarray(pos, jnp.int32)
        positions = (jnp.full((B, 1), p_) if p_.ndim == 0 else p_[:, None])
    cos, sin = _cos_sin(cfg, positions)

    def block_decode(lp, h, c):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        if cfg.attention_type == "mla":
            o, c = attn.mla_decode(lp["attn"], cfg, x, cos, sin, c, pos)
        else:
            o, c = attn.gqa_decode(lp["attn"], cfg, x, cos, sin, c, pos)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if cfg.has_moe and "router" in lp["ffn"]:
            y, _ = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
        else:
            y = ffn(lp["ffn"], cfg, x)
        return h + y, c

    new_prefix = []
    for lp, c in zip(params.get("prefix_layers", []), cache.get("prefix", [])):
        h, c = block_decode(lp, h, c)
        new_prefix.append(c)

    def scan_body(h, xs):
        lp, c = xs
        h, c = block_decode(lp, h, c)
        return h, c

    h, new_stack = jax.lax.scan(scan_body, h, (params["layers"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params, cfg, h[:, -1])
    new_cache = {"stack": new_stack}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return logits, new_cache


# ---------------------------------------------------------------------------
# paged prefill / decode (block-pool KV cache; see serving/kvpool.py)
#
# Attention inside these trunks is dispatched per backend through the
# kernel registry (kernels/ops.kernel_mode): the Mosaic Pallas
# paged_decode_attention / paged_prefill_attention kernels on TPU,
# interpret-executed kernels for kernel tests, and the jnp reference math
# on CPU. The dispatch decision is read at trace time, i.e. once per
# compiled engine step — not per token.


def supports_paged(cfg: ModelConfig) -> bool:
    """Paged caching covers the GQA transformer trunk (dense + MoE).
    MLA/SSM/hybrid/enc-dec state and ring-buffer windows keep their dense
    layouts; sequences there fall back to the dense engine."""
    return (cfg.family in ("dense", "moe") and cfg.attention_type == "gqa"
            and cfg.sliding_window is None)


def supports_chunked(cfg: ModelConfig) -> bool:
    """Chunked (token-budget) prefill needs an append-able linear KV
    layout: the GQA trunk qualifies on BOTH cache disciplines (paged
    block tables and the dense per-slot cache share ``lm_chunk_prefill``
    via their gather/scatter pairs). Ring-buffer sliding windows, MLA
    latent caches and SSM/enc-dec state fall back to whole-prompt
    prefill — the engine still schedules them under the same token
    budget, as one maximal chunk."""
    return supports_paged(cfg)


def lm_paged_prefill(
    params: Params, cfg: ModelConfig, tokens: jnp.ndarray, ctx_kv: Params,
    start, s_real, *, moe_cf=1.25,
) -> Tuple[jnp.ndarray, Params]:
    """Compute pass of a paged suffix prefill (no pool access).

    Prefill is split in three so the pool is never re-materialized:
    ``attn.paged_gather_ctx`` reads the cached context blocks (small),
    this function runs the model over the uncached suffix against that
    gathered context, and ``attn.paged_scatter`` writes the returned
    suffix KV into the request's blocks in place (donated buffer).

    tokens: (1, Sb) suffix right-padded to a bucket; ctx_kv: gathered
    context KV (same pytree shape as the pool, block axes merged);
    start: tokens already cached (prefix hit); s_real: live suffix
    tokens. Returns (logits of the last live token (1, V), suffix KV)."""
    h = embed_tokens(params, cfg, tokens)
    _, Sb, _ = h.shape
    positions = (start + jnp.arange(Sb))[None, :]
    cos, sin = _cos_sin(cfg, positions)

    def block(lp, h, c):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        o, kv = attn.gqa_paged_prefill(lp["attn"], cfg, x, cos, sin, c,
                                       start, s_real)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if cfg.has_moe and "router" in lp["ffn"]:
            y, _ = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
        else:
            y = ffn(lp["ffn"], cfg, x)
        return h + y, kv

    new_prefix = []
    for lp, c in zip(params.get("prefix_layers", []), ctx_kv.get("prefix", [])):
        h, kv = block(lp, h, c)
        new_prefix.append(kv)

    def scan_body(h, xs):
        lp, c = xs
        h, kv = block(lp, h, c)
        return h, kv

    h, new_stack = jax.lax.scan(scan_body, h,
                                (params["layers"], ctx_kv["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    h_last = jax.lax.dynamic_slice_in_dim(h, jnp.maximum(s_real - 1, 0), 1,
                                          axis=1)[:, 0]
    new_kv = {"stack": new_stack}
    if new_prefix:
        new_kv["prefix"] = new_prefix
    return unembed(params, cfg, h_last), new_kv


# The chunk-prefill trunk is cache-layout agnostic: ``ctx_kv`` is "this
# sequence's cached KV in token order", however it was gathered — through
# a block table (attn.paged_gather_ctx) or out of a dense slot
# (attn.dense_gather_slot). Continuous batching runs a prompt through it
# one chunk at a time, advancing ``start`` per chunk.
lm_chunk_prefill = lm_paged_prefill


def lm_paged_decode(
    params: Params, cfg: ModelConfig, token: jnp.ndarray, cache: Params,
    block_tables: jnp.ndarray, pos, *, moe_cf=None,
) -> Tuple[jnp.ndarray, Params]:
    """One-token step against the block pool. token: (B, 1) int32;
    block_tables: (B, NBseq); pos: (B,) global token index, -1 for
    inactive slots. Returns (logits (B, V), updated pool)."""
    h = params["embed"][token].astype(_adtype(cfg))
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.maximum(pos, 0)[:, None]
    cos, sin = _cos_sin(cfg, positions)

    def block(lp, h, c):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        o, c = attn.gqa_paged_decode(lp["attn"], cfg, x, cos, sin, c,
                                     block_tables, pos)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if cfg.has_moe and "router" in lp["ffn"]:
            y, _ = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
        else:
            y = ffn(lp["ffn"], cfg, x)
        return h + y, c

    new_prefix = []
    for lp, c in zip(params.get("prefix_layers", []), cache.get("prefix", [])):
        h, c = block(lp, h, c)
        new_prefix.append(c)

    def scan_body(h, xs):
        lp, c = xs
        h, c = block(lp, h, c)
        return h, c

    h, new_stack = jax.lax.scan(scan_body, h, (params["layers"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params, cfg, h[:, -1])
    new_cache = {"stack": new_stack}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return logits, new_cache


# ---------------------------------------------------------------------------
# speculative verify (multi-token batched decode with all-position logits)
#
# The draft/verify step of speculative decoding: every batch row feeds
# its last sampled token plus K drafted tokens in ONE forward and gets
# logits back at EVERY position (the chunk-prefill trunk computes the
# full hidden state too, but unembeds only the last live token — verify
# needs them all, so these wrappers share the block/scan structure and
# differ only in the attention primitive and the final unembed).


def lm_paged_verify(
    params: Params, cfg: ModelConfig, tokens: jnp.ndarray, cache: Params,
    block_tables: jnp.ndarray, pos, max_pos=None, *, moe_cf=None,
) -> Tuple[jnp.ndarray, Params]:
    """Batched S-token verify step against the block pool.
    tokens: (B, S) int32 — row layout [last_token, draft_1..draft_{S-1}];
    pos: (B,) global index of tokens[:, 0], -1 for inactive rows;
    max_pos: (B,) optional per-row KV-write cap (see gqa_paged_verify).
    Returns (logits (B, S, V) at every fed position, updated pool)."""
    h = embed_tokens(params, cfg, tokens)
    _, S, _ = h.shape
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.maximum(pos, 0)[:, None] + jnp.arange(S)[None, :]
    cos, sin = _cos_sin(cfg, positions)

    def block(lp, h, c):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        o, c = attn.gqa_paged_verify(lp["attn"], cfg, x, cos, sin, c,
                                     block_tables, pos, max_pos)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if cfg.has_moe and "router" in lp["ffn"]:
            y, _ = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
        else:
            y = ffn(lp["ffn"], cfg, x)
        return h + y, c

    new_prefix = []
    for lp, c in zip(params.get("prefix_layers", []), cache.get("prefix", [])):
        h, c = block(lp, h, c)
        new_prefix.append(c)

    def scan_body(h, xs):
        lp, c = xs
        h, c = block(lp, h, c)
        return h, c

    h, new_stack = jax.lax.scan(scan_body, h, (params["layers"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    new_cache = {"stack": new_stack}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return unembed(params, cfg, h), new_cache


def lm_dense_verify(
    params: Params, cfg: ModelConfig, tokens: jnp.ndarray, cache: Params,
    pos, *, moe_cf=None,
) -> Tuple[jnp.ndarray, Params]:
    """Batched S-token verify step against the dense per-slot cache —
    same contract as ``lm_paged_verify`` without block tables."""
    h = embed_tokens(params, cfg, tokens)
    _, S, _ = h.shape
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.maximum(pos, 0)[:, None] + jnp.arange(S)[None, :]
    cos, sin = _cos_sin(cfg, positions)

    def block(lp, h, c):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        o, c = attn.gqa_dense_verify(lp["attn"], cfg, x, cos, sin, c, pos)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        if cfg.has_moe and "router" in lp["ffn"]:
            y, _ = moe_ffn(lp["ffn"], cfg, x, capacity_factor=moe_cf)
        else:
            y = ffn(lp["ffn"], cfg, x)
        return h + y, c

    new_prefix = []
    for lp, c in zip(params.get("prefix_layers", []), cache.get("prefix", [])):
        h, c = block(lp, h, c)
        new_prefix.append(c)

    def scan_body(h, xs):
        lp, c = xs
        h, c = block(lp, h, c)
        return h, c

    h, new_stack = jax.lax.scan(scan_body, h, (params["layers"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    new_cache = {"stack": new_stack}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return unembed(params, cfg, h), new_cache


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=None) -> Params:
    """Global KV block pool: every leaf is (num_blocks, block_size, ...)
    — one population of blocks shared by all sequences on the engine,
    leased out through serving/kvpool.py block tables."""
    assert supports_paged(cfg), f"{cfg.name}: no paged cache for this family"
    dtype = dtype or _adtype(cfg)
    n_prefix = cfg.first_dense_layers if cfg.has_moe else 0
    n_stack = cfg.num_layers - n_prefix

    if cfg.kv_cache_dtype == "int8":
        def one(lead=()):
            kv_shape = lead + (num_blocks, block_size, cfg.num_kv_heads,
                               cfg.head_dim)
            sc_shape = lead + (num_blocks, block_size, cfg.num_kv_heads, 1)
            return {
                "k": jnp.zeros(kv_shape, jnp.int8),
                "k_scale": jnp.zeros(sc_shape, jnp.float32),
                "v": jnp.zeros(kv_shape, jnp.int8),
                "v_scale": jnp.zeros(sc_shape, jnp.float32),
            }
    else:
        def one(lead=()):
            shape = lead + (num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    cache: Params = {"stack": one(lead=(n_stack,))}
    if n_prefix:
        cache["prefix"] = [one() for _ in range(n_prefix)]
    return cache


def copy_paged_block(cache: Params, src, dst) -> Params:
    """Copy-on-write helper: duplicate block ``src`` into ``dst`` across
    every layer and leaf of the pool (a shared prefix block a request
    must append into is copied first; see kvpool.RadixPrefixCache)."""
    def cp(arr):
        axis = arr.ndim - 4          # block axis: (..., NB, BS, H, D/1)
        blk = jax.lax.dynamic_index_in_dim(arr, src, axis=axis)
        return jax.lax.dynamic_update_index_in_dim(arr, blk, dst, axis=axis)

    return jax.tree_util.tree_map(cp, cache)


# ---------------------------------------------------------------------------
# cache construction (also used by the dry-run via jax.eval_shape)


def init_lm_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=None) -> Params:
    dtype = dtype or _adtype(cfg)
    eff = cache_len if cfg.sliding_window is None else min(cfg.sliding_window, cache_len)
    n_prefix = cfg.first_dense_layers if cfg.has_moe else 0
    n_stack = cfg.num_layers - n_prefix

    if cfg.attention_type == "mla":
        def one(lead=()):
            return {
                "ckv": jnp.zeros(lead + (batch, eff, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros(lead + (batch, eff, cfg.qk_rope_head_dim), dtype),
            }
    elif cfg.kv_cache_dtype == "int8":
        def one(lead=()):
            kv_shape = lead + (batch, eff, cfg.num_kv_heads, cfg.head_dim)
            sc_shape = lead + (batch, eff, cfg.num_kv_heads, 1)
            return {
                "k": jnp.zeros(kv_shape, jnp.int8),
                "k_scale": jnp.zeros(sc_shape, jnp.float32),
                "v": jnp.zeros(kv_shape, jnp.int8),
                "v_scale": jnp.zeros(sc_shape, jnp.float32),
            }
    else:
        def one(lead=()):
            return {
                "k": jnp.zeros(lead + (batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros(lead + (batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype),
            }

    cache: Params = {"stack": one(lead=(n_stack,))}
    if n_prefix:
        cache["prefix"] = [one() for _ in range(n_prefix)]
    return cache
