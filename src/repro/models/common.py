"""Shared building blocks for the model zoo.

Pure-functional JAX: params are nested dicts of jnp arrays; every module is
an ``init_*`` + ``apply`` function pair. Layer stacks are stored with a
leading ``num_layers`` axis so the trunk can run under ``jax.lax.scan``
(small HLO, tractable compile times at 64 layers — see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Params = dict

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02).astype(dtype)


def stack_init(key, n: int, init_fn):
    """Initialize ``n`` layers and stack each leaf along axis 0 (for scan)."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *layers)


# ---------------------------------------------------------------------------
# norms


def init_rmsnorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,). float32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for integer positions of any shape -> (*pos, half)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, half).

    Rotates the (paired-halves) convention: x = [x1, x2] -> [x1*c - x2*s,
    x2*c + x1*s], matching llama-style RoPE.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :].astype(x.dtype)  # broadcast over heads axis
    sin = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_cos_sin(
    positions: jnp.ndarray,      # (..., seq, 3) — (t, h, w) triplets
    sections: Tuple[int, ...],   # per-section half-dims, sum = head_dim // 2
    theta: float,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL M-RoPE: the rotary half-dims are split into (t, h, w)
    sections, each rotated by the corresponding position coordinate.
    Text tokens use t == h == w, which reduces to standard RoPE.
    Returns cos/sin of shape (..., seq, head_dim // 2).
    """
    head_dim = 2 * sum(sections)
    inv = rope_freqs(head_dim, theta)           # (half,)
    # section id per frequency slot
    cos_parts, sin_parts = [], []
    start = 0
    for i, sec in enumerate(sections):
        pos_i = positions[..., i].astype(jnp.float32)          # (..., seq)
        ang = pos_i[..., None] * inv[start:start + sec]
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    return jnp.concatenate(cos_parts, axis=-1), jnp.concatenate(sin_parts, axis=-1)


# ---------------------------------------------------------------------------
# activations


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
