"""Attention: GQA (full / sliding-window / decode) and MLA (DeepSeek-V2).

Three execution modes per layer:
  * ``train`` / ``prefill`` — chunked online-softmax attention in pure JAX
    (``flash_attention_jnp``): O(q_chunk x kv) live memory so 32k prefill
    lowers without materializing (S x S) scores. The Pallas kernels in
    ``repro.kernels`` implement the same contract for the TPU hot path and
    are validated against these semantics.
  * ``decode`` — one new token against a cache: either a full linear cache
    or a ring-buffer sliding-window cache (keys RoPE'd at write time, so
    ring order is irrelevant to softmax).
  * ``cross`` — encoder-decoder cross attention over precomputed KV.

Caches are per-layer dicts of arrays; the trunk stacks them with a leading
``num_layers`` axis for ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, apply_rope, dense_init

NEG_INF = -1e30


def _kernel_dispatch(cache_like: Params) -> Optional[bool]:
    """Paged-attention dispatch decision for the engine hot path (see
    ``kernels.ops`` registry): None — run the jnp reference trunk;
    otherwise the Pallas kernel's ``interpret`` flag (False: Mosaic on
    TPU). int8 KV pools always take the reference trunk — the kernels
    stream raw k/v blocks, not (values, scales) pairs."""
    from repro.kernels import ops
    mode = ops.kernel_mode()
    if mode == "reference" or "k_scale" in cache_like:
        return None
    return mode != "mosaic"


def dyn_write(cache: jnp.ndarray, new: jnp.ndarray, pos) -> jnp.ndarray:
    """Write ``new`` (B, 1, ...) into ``cache`` (B, S, ...) at sequence
    position ``pos`` (scalar, or (B,) for ragged continuous batching)."""
    pos = jnp.asarray(pos, jnp.int32)
    new = new.astype(cache.dtype)
    if pos.ndim == 0:
        start = (0, pos) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new, start)

    def one(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (p,) + (0,) * (c.ndim - 1))

    return jax.vmap(one)(cache, new, pos)


# ---------------------------------------------------------------------------
# init


def init_gqa(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype, scale=1.0 / math.sqrt(hq * hd)),
    }
    if cfg.use_qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.use_attn_out_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def init_mla(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    qn, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    q_in = cfg.q_lora_rank or d
    p = {
        "w_dkv": dense_init(ks[2], d, cfg.kv_lora_rank + qr, dtype),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora_rank,), dtype)},
        "w_uk": dense_init(ks[3], cfg.kv_lora_rank, h * qn, dtype),
        "w_uv": dense_init(ks[4], cfg.kv_lora_rank, h * vh, dtype),
        "wo": dense_init(ks[5], h * vh, d, dtype, scale=1.0 / math.sqrt(h * vh)),
    }
    if cfg.q_lora_rank:
        kq = jax.random.split(ks[0], 2)
        p["w_dq"] = dense_init(kq[0], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = {"scale": jnp.ones((cfg.q_lora_rank,), dtype)}
        p["w_uq"] = dense_init(kq[1], cfg.q_lora_rank, h * (qn + qr), dtype)
    else:
        p["w_uq"] = dense_init(ks[0], d, h * (qn + qr), dtype)
    return p


# ---------------------------------------------------------------------------
# chunked online-softmax attention (pure JAX; mirrors the Pallas kernel)


def flash_attention_jnp(
    q: jnp.ndarray,            # (B, Sq, Hq, D)
    k: jnp.ndarray,            # (B, Skv, Hkv, D)
    v: jnp.ndarray,            # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    q_offset=0,                # global position of q[0] (int or traced scalar)
    scale: Optional[float] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,   # (B,) valid kv prefix
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    q = q.reshape(B, Sq, Hkv, G, D)
    qc = min(q_chunk, Sq)
    n_chunks = (Sq + qc - 1) // qc
    pad = n_chunks * qc - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    q = q.reshape(B, n_chunks, qc, Hkv, G, D)
    q = jnp.moveaxis(q, 1, 0)  # (n_chunks, B, qc, Hkv, G, D)

    kv_pos = jnp.arange(Skv)

    def chunk_body(carry, inp):
        ci, qi = inp
        q_pos = q_offset + ci * qc + jnp.arange(qc)
        # logits: (B, qc, Hkv, G, Skv)
        logits = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        mask = jnp.ones((qc, Skv), dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask_b = mask[None, :, None, None, :]
        if kv_valid_len is not None:
            valid = kv_pos[None, :] < kv_valid_len[:, None]     # (B, Skv)
            mask_b = mask_b & valid[:, None, None, None, :]
        logits = jnp.where(mask_b, logits, NEG_INF)
        out = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bqhgk,bkhd->bqhgd", out, v.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    chunk_body = jax.checkpoint(chunk_body)
    _, outs = jax.lax.scan(chunk_body, None, (jnp.arange(n_chunks), q))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_chunks * qc, Hkv, G, Dv)
    if pad:
        out = out[:, :Sq]
    return out.reshape(B, Sq, Hq, Dv)


def decode_attention_jnp(
    q: jnp.ndarray,            # (B, 1, Hq, D)
    k_cache: jnp.ndarray,      # (B, S, Hkv, D)
    v_cache: jnp.ndarray,      # (B, S, Hkv, Dv)
    valid_len: jnp.ndarray,    # scalar or (B,): number of written entries
    *,
    ring: bool = False,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention against a cache. ``ring=True`` means the cache is
    a ring buffer (all slots < min(valid_len, S) are live past tokens)."""
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    Dv = v_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qv = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qv.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    slot = jnp.arange(S)
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = jnp.broadcast_to(vl, (B,))
    cap = jnp.minimum(vl, S) if ring else vl
    live = slot[None, :] < cap[:, None]                     # (B, S)
    logits = jnp.where(live[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer forward


def _proj_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _out_proj(p: Params, cfg: ModelConfig, o: jnp.ndarray):
    B, S = o.shape[:2]
    o = o.reshape(B, S, -1) @ p["wo"].astype(o.dtype)
    if "bo" in p:
        o = o + p["bo"].astype(o.dtype)
    return o


def gqa_full(params: Params, cfg: ModelConfig, x, cos, sin, *,
             causal: bool = True, q_chunk: int = 512) -> jnp.ndarray:
    """Training / encoder forward (no cache)."""
    q, k, v = _proj_qkv(params, cfg, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = flash_attention_jnp(q, k, v, causal=causal,
                            window=cfg.sliding_window, q_chunk=q_chunk)
    return _out_proj(params, cfg, o)


def gqa_prefill(params: Params, cfg: ModelConfig, x, cos, sin, cache_len: int,
                q_chunk: int = 512) -> Tuple[jnp.ndarray, Params]:
    """Causal forward that also returns the populated per-layer cache.

    Full cache: (B, cache_len, Hkv, D) zero-padded past S.
    Sliding window: ring layout of the last ``window`` keys (cache_len is
    the window size in that case).
    """
    B, S, _ = x.shape
    q, k, v = _proj_qkv(params, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = flash_attention_jnp(q, k, v, causal=True, window=cfg.sliding_window,
                            q_chunk=q_chunk)
    w = cfg.sliding_window
    if w is not None:
        # keep the last `window` tokens, laid out at ring slots pos % window
        last = max(S - w, 0)
        idx_tok = last + jnp.arange(min(w, S))
        ring_slot = idx_tok % w
        kc = jnp.zeros((B, w, cfg.num_kv_heads, cfg.head_dim), k.dtype)
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, ring_slot].set(k[:, idx_tok])
        vc = vc.at[:, ring_slot].set(v[:, idx_tok])
        cache = _pack_kv(cfg, kc, vc)
    else:
        pad = cache_len - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = _pack_kv(cfg, kc, vc)
    return _out_proj(params, cfg, o), cache


def _pack_kv(cfg: ModelConfig, k: jnp.ndarray, v: jnp.ndarray) -> Params:
    """Cache layout: bf16 {k, v} or int8 {k, k_scale, v, v_scale}
    (per-token-per-head absmax; §Perf H1 iteration 3)."""
    if cfg.kv_cache_dtype != "int8":
        return {"k": k, "v": v}
    from repro.serving.kvquant import quantize
    kq, ks = quantize(k)
    vq, vs = quantize(v)
    return {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}


def _unpack_kv(cfg: ModelConfig, cache: Params):
    if "k_scale" not in cache:
        return cache["k"], cache["v"]
    from repro.serving.kvquant import dequantize
    return (dequantize(cache["k"], cache["k_scale"]),
            dequantize(cache["v"], cache["v_scale"]))


def gqa_decode(params: Params, cfg: ModelConfig, x, cos, sin,
               cache: Params, pos) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. ``pos`` is the global index of the new token
    (scalar int32). Returns (out, updated cache)."""
    B = x.shape[0]
    q, k, v = _proj_qkv(params, cfg, x)           # S == 1
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    w = cfg.sliding_window
    ring = w is not None
    slot = (jnp.asarray(pos) % w) if ring else pos
    if "k_scale" in cache:
        from repro.serving.kvquant import quantize
        kq, ks = quantize(k)
        vq, vs = quantize(v)
        new_cache = {"k": dyn_write(cache["k"], kq, slot),
                     "k_scale": dyn_write(cache["k_scale"], ks, slot),
                     "v": dyn_write(cache["v"], vq, slot),
                     "v_scale": dyn_write(cache["v_scale"], vs, slot)}
    else:
        new_cache = {"k": dyn_write(cache["k"], k, slot),
                     "v": dyn_write(cache["v"], v, slot)}
    kc, vc = _unpack_kv(cfg, new_cache)
    o = decode_attention_jnp(q, kc, vc, jnp.asarray(pos) + 1, ring=ring)
    return _out_proj(params, cfg, o), new_cache


# ---------------------------------------------------------------------------
# paged GQA (block-pool KV cache; serving/kvpool.py owns the block ids)
#
# The cache is a GLOBAL pool of KV blocks shaped (num_blocks, block_size,
# Hkv, D) shared by every sequence on the engine; a sequence's KV for
# token position p lives at pool[table[p // bs], p % bs]. These jnp paths
# define the semantics the Pallas kernel (kernels/paged_attention.py)
# implements for the TPU hot path: they gather the leased blocks into
# token order and reuse the dense attention math, so a paged engine is
# arithmetically identical to the dense one.


def _paged_parts(pool: Params):
    k = pool["k"]
    nb, bs = k.shape[0], k.shape[1]
    flat = {name: arr.reshape((nb * bs,) + arr.shape[2:])
            for name, arr in pool.items()}
    return flat, nb, bs


def _paged_write(pool: Params, k: jnp.ndarray, v: jnp.ndarray,
                 flat_idx: jnp.ndarray) -> Params:
    """Scatter new tokens into the pool. ``k``/``v``: (N, Hkv, D) with
    leading dim matching ``flat_idx`` (token-flat pool indices; entries
    >= num_blocks*block_size are dropped — padded/inactive writes)."""
    flat, nb, bs = _paged_parts(pool)
    if "k_scale" in pool:
        from repro.serving.kvquant import quantize
        kq, ks = quantize(k)
        vq, vs = quantize(v)
        new = {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}
    else:
        new = {"k": k, "v": v}
    out = {}
    for name, arr in flat.items():
        upd = new[name].astype(arr.dtype)
        arr = arr.at[flat_idx].set(upd, mode="drop")
        out[name] = arr.reshape(pool[name].shape)
    return out


def _paged_gather(cfg: ModelConfig, pool: Params, flat_idx: jnp.ndarray):
    """Read tokens back out of the pool in sequence order.
    ``flat_idx``: (..., S) token-flat indices -> (kc, vc) (..., S, Hkv, D)."""
    flat, _, _ = _paged_parts(pool)
    gathered = {name: arr[flat_idx] for name, arr in flat.items()}
    return _unpack_kv(cfg, gathered)


def paged_gather_ctx(cache: Params, table_ctx: jnp.ndarray) -> Params:
    """Lease-read the context blocks of one sequence out of the pool:
    every leaf (..., NB, BS, H, D) -> (..., ctx*BS, H, D) in token order.
    A pure read — the pool buffer is never rewritten (that is the whole
    reason prefill splits into gather / compute / scatter)."""
    def take(leaf):
        g = jnp.take(leaf, table_ctx, axis=leaf.ndim - 4)
        shp = g.shape
        merged = shp[:leaf.ndim - 4] + (shp[leaf.ndim - 4] * shp[leaf.ndim - 3],)
        return g.reshape(merged + shp[leaf.ndim - 2:])

    return jax.tree_util.tree_map(take, cache)


def paged_scatter(cache: Params, new_kv: Params, block_table: jnp.ndarray,
                  start, s_real) -> Params:
    """Write a request's freshly-computed suffix KV into its pool blocks
    (positions ``start .. start+s_real-1`` through ``block_table``).
    Compiled with the pool donated: the update aliases in place, costing
    O(suffix), not O(pool). Leaves pair as (..., NB, BS, H, D) with
    (..., Sb, H, D)."""
    k0 = new_kv["stack"]["k"] if "stack" in new_kv else new_kv["k"]
    Sb = k0.shape[-3]
    nb = (cache["stack"]["k"] if "stack" in cache else cache["k"]).shape[-4]
    bs = (cache["stack"]["k"] if "stack" in cache else cache["k"]).shape[-3]
    pos = start + jnp.arange(Sb)
    blk = block_table[jnp.clip(pos // bs, 0, block_table.shape[0] - 1)]
    blk = jnp.where(jnp.arange(Sb) < s_real, blk, nb)          # drop pads
    off = pos % bs

    def put(leaf, upd):
        upd = upd.astype(leaf.dtype)
        if leaf.ndim == 5:                        # stacked layers leading
            return leaf.at[:, blk, off].set(upd, mode="drop")
        return leaf.at[blk, off].set(upd, mode="drop")

    return jax.tree_util.tree_map(put, cache, new_kv)


def gqa_paged_prefill(params: Params, cfg: ModelConfig, x, cos, sin,
                      ctx_kv: Params, start, s_real
                      ) -> Tuple[jnp.ndarray, Params]:
    """Suffix prefill of one layer against gathered context KV.

    ``x``: (1, Sb, d) — the UNCACHED tail of the prompt, right-padded to
    a bucket; ``ctx_kv``: this layer's pool blocks gathered in token
    order (``paged_gather_ctx``), entries >= ``start`` masked out;
    ``s_real`` <= Sb is the count of live (non-pad) suffix tokens.
    Queries run at global offset ``start`` so causality and RoPE line up
    with the cached prefix. Returns (out, packed suffix KV for
    ``paged_scatter``) — the pool itself is untouched here.

    Kernel dispatch: under ``mosaic``/``interpret`` the chunk attends
    through ``kernels.paged_prefill_attention`` — the gathered context
    is presented as ONE pool block (the kernel's block-table contract
    covers any block size), the chunk's fresh KV rides as operands, and
    one online softmax streams context + self causally. The jnp math
    below is the ``reference`` trunk the kernel is validated against."""
    B, Sb, _ = x.shape
    q, k, v = _proj_qkv(params, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kc, vc = _unpack_kv(cfg, ctx_kv)              # (CtxT, Hkv, D)
    CtxT = kc.shape[0]
    interpret = _kernel_dispatch(ctx_kv)
    if interpret is not None:
        from repro.kernels import ops
        o = ops.paged_prefill_attention(
            q[0], kc[None], vc[None], k[0], v[0],
            jnp.zeros((1,), jnp.int32), start, s_real,
            interpret=interpret)[None]
        return _out_proj(params, cfg, o.astype(x.dtype)), _pack_kv(cfg, k[0], v[0])
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qg = q.reshape(B, Sb, Hkv, G, cfg.head_dim).astype(jnp.float32)
    kfull = jnp.concatenate([kc[None].astype(jnp.float32),
                             k.astype(jnp.float32)], axis=1)   # (1, K, H, D)
    vfull = jnp.concatenate([vc[None].astype(jnp.float32),
                             v.astype(jnp.float32)], axis=1)
    logits = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kfull) * scale
    i = jnp.arange(Sb)
    live_ctx = jnp.broadcast_to((jnp.arange(CtxT) < start)[None, :],
                                (Sb, CtxT))
    live_new = (i[None, :] <= i[:, None]) & (i[None, :] < s_real)
    mask = jnp.concatenate([live_ctx, live_new], axis=1)       # (Sb, K)
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", w, vfull)
    o = o.reshape(B, Sb, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    return _out_proj(params, cfg, o), _pack_kv(cfg, k[0], v[0])


def gqa_paged_decode(params: Params, cfg: ModelConfig, x, cos, sin,
                     pool: Params, block_tables: jnp.ndarray, pos
                     ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode against a paged cache. ``block_tables``:
    (B, NBseq) pool block ids; ``pos``: (B,) global index of the new
    token, or -1 for inactive batch slots (their write is dropped and
    their output is garbage the engine ignores).

    Kernel dispatch: under ``mosaic``/``interpret`` the attention runs
    through ``kernels.paged_decode_attention`` directly against the pool
    — each sequence's blocks are streamed through its scalar-prefetched
    table, with NO gathered (B, Smax) KV copy materialized per step (the
    reference trunk's gather exists to reuse the dense math, not because
    the contract needs it). Inactive rows carry valid_len 0 — every
    block is skipped and the flushed output is the garbage the engine
    ignores."""
    B = x.shape[0]
    q, k, v = _proj_qkv(params, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    _, nb, bs = _paged_parts(pool)
    pos = jnp.asarray(pos, jnp.int32)
    safe = jnp.maximum(pos, 0)
    blk = jnp.take_along_axis(block_tables, (safe // bs)[:, None], axis=1)[:, 0]
    flat = jnp.where(pos >= 0, blk * bs + safe % bs, nb * bs)
    pool = _paged_write(pool, k[:, 0], v[:, 0], flat)
    interpret = _kernel_dispatch(pool)
    if interpret is not None:
        from repro.kernels import ops
        o = ops.paged_decode_attention(q[:, 0], pool["k"], pool["v"],
                                       block_tables, pos + 1,
                                       interpret=interpret)[:, None]
        return _out_proj(params, cfg, o.astype(x.dtype)), pool
    t = jnp.arange(block_tables.shape[1] * bs)
    gflat = jnp.take(block_tables, t // bs, axis=1) * bs + t % bs  # (B, Smax)
    kc, vc = _paged_gather(cfg, pool, gflat)
    o = decode_attention_jnp(q, kc, vc, pos + 1)
    return _out_proj(params, cfg, o), pool


def gqa_paged_verify(params: Params, cfg: ModelConfig, x, cos, sin,
                     pool: Params, block_tables: jnp.ndarray, pos,
                     max_pos=None) -> Tuple[jnp.ndarray, Params]:
    """S-token speculative verify step of one layer against the block
    pool — the batched sibling of ``gqa_paged_decode``: every row feeds
    ``S`` consecutive tokens (its last sampled token plus S-1 drafted
    ones) at positions ``pos .. pos+S-1``, writes their KV through its
    block table, and attends causally over the full cached sequence.

    ``pos``: (B,) global index of ``x[:, 0]``, -1 for inactive rows
    (writes dropped, output garbage the engine masks). ``max_pos``:
    (B,) last position each row may legitimately write — a fed span can
    extend past a row's LEASED blocks (the table's zero padding would
    alias block 0, clobbering another request's KV), so writes beyond
    it are dropped; the engine's on-device max_new/room masks stop
    emission before those positions matter. Stale pool entries past a
    row's cursor are rewritten by this chunk before the gather, so the
    attention only ever sees valid KV."""
    B, S, _ = x.shape
    q, k, v = _proj_qkv(params, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    _, nb, bs = _paged_parts(pool)
    nbseq = block_tables.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    p = jnp.maximum(pos, 0)[:, None] + jnp.arange(S)[None, :]      # (B, S)
    blk = jnp.take_along_axis(block_tables,
                              jnp.clip(p // bs, 0, nbseq - 1), axis=1)
    ok = (pos[:, None] >= 0) & (p < nbseq * bs)
    if max_pos is not None:
        ok = ok & (p <= jnp.asarray(max_pos, jnp.int32)[:, None])
    flat = jnp.where(ok, blk * bs + p % bs, nb * bs)               # drop
    pool = _paged_write(pool, k.reshape(B * S, cfg.num_kv_heads,
                                        cfg.head_dim),
                        v.reshape(B * S, cfg.num_kv_heads, cfg.head_dim),
                        flat.reshape(B * S))
    t = jnp.arange(nbseq * bs)
    gflat = jnp.take(block_tables, t // bs, axis=1) * bs + t % bs  # (B, Smax)
    kc, vc = _paged_gather(cfg, pool, gflat)
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qg = q.reshape(B, S, Hkv, G, cfg.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bqhgk", qg,
                        kc.astype(jnp.float32)) * scale
    live = jnp.arange(nbseq * bs)[None, None, :] <= p[:, :, None]  # (B, S, K)
    logits = jnp.where(live[:, :, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", w, vc.astype(jnp.float32))
    o = o.reshape(B, S, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    return _out_proj(params, cfg, o), pool


def gqa_dense_verify(params: Params, cfg: ModelConfig, x, cos, sin,
                     cache: Params, pos) -> Tuple[jnp.ndarray, Params]:
    """S-token speculative verify step of one layer against a dense
    (B, Smax) per-slot cache — same contract as ``gqa_paged_verify``
    with slot rows instead of block tables (``pos`` -1 = inactive,
    writes dropped)."""
    B, S, _ = x.shape
    q, k, v = _proj_qkv(params, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    Smax = cache["k"].shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    p = jnp.maximum(pos, 0)[:, None] + jnp.arange(S)[None, :]      # (B, S)
    pw = jnp.where((pos[:, None] >= 0) & (p < Smax), p, Smax)      # drop
    bi = jnp.arange(B)[:, None]
    if "k_scale" in cache:
        from repro.serving.kvquant import quantize
        kq, ks = quantize(k)
        vq, vs = quantize(v)
        new_cache = {
            "k": cache["k"].at[bi, pw].set(kq.astype(cache["k"].dtype),
                                           mode="drop"),
            "k_scale": cache["k_scale"].at[bi, pw].set(ks, mode="drop"),
            "v": cache["v"].at[bi, pw].set(vq.astype(cache["v"].dtype),
                                           mode="drop"),
            "v_scale": cache["v_scale"].at[bi, pw].set(vs, mode="drop")}
    else:
        new_cache = {
            "k": cache["k"].at[bi, pw].set(k.astype(cache["k"].dtype),
                                           mode="drop"),
            "v": cache["v"].at[bi, pw].set(v.astype(cache["v"].dtype),
                                           mode="drop")}
    kc, vc = _unpack_kv(cfg, new_cache)                            # (B, Smax)
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qg = q.reshape(B, S, Hkv, G, cfg.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bqhgk", qg,
                        kc.astype(jnp.float32)) * scale
    live = jnp.arange(Smax)[None, None, :] <= p[:, :, None]
    logits = jnp.where(live[:, :, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", w, vc.astype(jnp.float32))
    o = o.reshape(B, S, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    return _out_proj(params, cfg, o), new_cache


# ---------------------------------------------------------------------------
# dense per-slot chunk append (continuous batching on the DENSE cache)
#
# The chunked-prefill trunk (lm_chunk_prefill == lm_paged_prefill) is
# layout-agnostic: it only needs "this sequence's cached KV in token
# order" as ctx_kv. The paged engine gathers that through a block table
# (paged_gather_ctx); the dense engine gathers one slot's rows out of its
# (B, S, ...) cache with these two helpers, so BOTH cache disciplines
# share one chunk-append code path — and one equivalence contract.


def _slot_axis(path) -> int:
    """Batch/slot axis of a dense-cache leaf: prefix-layer leaves are
    (B, S, ...), stacked-layer leaves are (L, B, S, ...)."""
    return 0 if any(getattr(k, "key", None) == "prefix" for k in path) else 1


def dense_gather_slot(cache: Params, slot) -> Params:
    """Read ONE slot's rows out of the dense cache: every leaf
    (..., B, S, H, D) -> (..., S, H, D) in token order. The result is the
    ``ctx_kv`` of a chunk prefill (entries >= ``start`` are masked by the
    compute, so stale rows past the cursor are harmless)."""
    def take(path, leaf):
        return jax.lax.dynamic_index_in_dim(leaf, slot, axis=_slot_axis(path),
                                            keepdims=False)
    return jax.tree_util.tree_map_with_path(take, cache)


def dense_scatter_slot(cache: Params, new_kv: Params, slot, start,
                       s_real) -> Params:
    """Write a chunk's fresh KV into one slot's rows at positions
    ``start .. start+s_real-1`` (bucket pads dropped). Compiled with the
    cache donated — an in-place O(chunk) update, not an O(cache) rebuild
    like admission's whole-row insert."""
    k0 = new_kv["stack"]["k"] if "stack" in new_kv else new_kv["k"]
    Sb = k0.shape[-3]

    def put(path, leaf, upd):
        S = leaf.shape[-3]
        pos = start + jnp.arange(Sb)
        pos = jnp.where(jnp.arange(Sb) < s_real, pos, S)       # drop pads
        upd = upd.astype(leaf.dtype)
        if _slot_axis(path) == 0:                   # (B, S, H, D)
            return leaf.at[slot, pos].set(upd, mode="drop")
        return leaf.at[:, slot, pos].set(upd, mode="drop")     # (L, B, S, ...)

    return jax.tree_util.tree_map_with_path(put, cache, new_kv)


def cross_kv(params: Params, cfg: ModelConfig, enc_out: jnp.ndarray):
    B, S, _ = enc_out.shape
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": k, "v": v}


def cross_attend(params: Params, cfg: ModelConfig, x, kv: Params,
                 q_chunk: int = 512) -> jnp.ndarray:
    B, S, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    o = flash_attention_jnp(q, kv["k"], kv["v"], causal=False, q_chunk=q_chunk)
    return _out_proj(params, cfg, o)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) — DeepSeek-V2


def _mla_q(params: Params, cfg: ModelConfig, x):
    B, S, _ = x.shape
    h = cfg.num_heads
    if cfg.q_lora_rank:
        cq = x @ params["w_dq"].astype(x.dtype)
        from repro.models.common import rmsnorm
        cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
        q = cq @ params["w_uq"].astype(x.dtype)
    else:
        q = x @ params["w_uq"].astype(x.dtype)
    q = q.reshape(B, S, h, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    return q[..., : cfg.qk_nope_head_dim], q[..., cfg.qk_nope_head_dim:]


def _mla_latent(params: Params, cfg: ModelConfig, x, cos, sin):
    """Compress x into the latent KV stream: c_kv (B,S,r), k_rope (B,S,dr)."""
    from repro.models.common import rmsnorm
    ckv = x @ params["w_dkv"].astype(x.dtype)
    c, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c = rmsnorm(params["kv_norm"], c, cfg.norm_eps)
    # k_rope is a single shared rotary key stream (one "head")
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]
    return c, k_rope


def mla_full(params: Params, cfg: ModelConfig, x, cos, sin, *,
             q_chunk: int = 512) -> jnp.ndarray:
    """Train/prefill MLA via naive expansion (cache-free)."""
    B, S, _ = x.shape
    h = cfg.num_heads
    qn, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(params, cfg, x)
    q_rope = apply_rope(q_rope, cos, sin)
    c, k_rope = _mla_latent(params, cfg, x, cos, sin)
    k_nope = (c @ params["w_uk"].astype(x.dtype)).reshape(B, S, h, qn)
    v = (c @ params["w_uv"].astype(x.dtype)).reshape(B, S, h, vh)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, S, h, qr))], axis=-1)
    scale = 1.0 / math.sqrt(qn + qr)
    o = flash_attention_jnp(q, k, v, causal=True, q_chunk=q_chunk, scale=scale)
    return o.reshape(B, S, -1) @ params["wo"].astype(x.dtype)


def mla_prefill(params: Params, cfg: ModelConfig, x, cos, sin, cache_len: int,
                q_chunk: int = 512) -> Tuple[jnp.ndarray, Params]:
    B, S, _ = x.shape
    out = mla_full(params, cfg, x, cos, sin, q_chunk=q_chunk)
    c, k_rope = _mla_latent(params, cfg, x, cos, sin)
    pad = cache_len - S
    cache = {
        "ckv": jnp.pad(c, ((0, 0), (0, pad), (0, 0))),
        "krope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
    }
    return out, cache


def mla_decode(params: Params, cfg: ModelConfig, x, cos, sin,
               cache: Params, pos) -> Tuple[jnp.ndarray, Params]:
    """Absorbed-matrices MLA decode: attention runs in the latent space, so
    the cache is (kv_lora + rope_dim) per token instead of 2*H*D — the MLA
    serving advantage."""
    B = x.shape[0]
    h = cfg.num_heads
    qn, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(params, cfg, x)            # (B,1,h,qn),(B,1,h,qr)
    q_rope = apply_rope(q_rope, cos, sin)
    c_new, krope_new = _mla_latent(params, cfg, x, cos, sin)
    ckv = dyn_write(cache["ckv"], c_new, pos)
    krope = dyn_write(cache["krope"], krope_new, pos)

    # absorb W_uk into q: q_lat (B,h,r)
    w_uk = params["w_uk"].astype(x.dtype).reshape(r, h, qn)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    logits = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                        ckv.astype(jnp.float32))
    logits += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                         krope.astype(jnp.float32))
    logits *= 1.0 / math.sqrt(qn + qr)
    S = ckv.shape[1]
    posb = jnp.broadcast_to(jnp.asarray(pos), (B,))
    live = jnp.arange(S)[None, None, :] <= posb[:, None, None]
    logits = jnp.where(live, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, ckv.astype(jnp.float32)).astype(x.dtype)
    # absorb W_uv on the way out
    w_uv = params["w_uv"].astype(x.dtype).reshape(r, h, vh)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv).reshape(B, 1, h * vh)
    out = o @ params["wo"].astype(x.dtype)
    return out, {"ckv": ckv, "krope": krope}
