"""Serving API v2: the typed completion protocol + futures-style handles.

The single public vocabulary of the Pick-and-Spin serve plane — see
``repro.core.gateway.ServeFrontend`` for the gateway that speaks it.
"""
from repro.api.protocol import (CompletionRequest, CompletionResponse,  # noqa: F401
                                FinishReason, Priority, StreamEvent, Usage)
from repro.api.handle import CompletionHandle  # noqa: F401
