"""Serving API v2 — the typed completion protocol.

One request/response vocabulary for every way of talking to the serve
plane: the synchronous ``Gateway`` facade, the concurrent
``ServeFrontend``, launchers, examples and benchmarks all speak
``CompletionRequest`` in and ``CompletionResponse`` out. Shedding,
cancellation and deadline expiry are STRUCTURED results (a response with
a ``finish_reason``), never ``None`` — a caller can always tell what
happened to a request it submitted.

``StreamEvent`` is the unit of streaming: one event per generated token
(emitted per decode iteration of the engine underneath) plus a terminal
``done`` event carrying the finish reason.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum
from typing import List, Optional

from repro.serving.sampling import SamplingParams


class Priority(IntEnum):
    """Request priority class. Under admission pressure the scheduler
    sheds strictly low-before-high: a queued BATCH request is evicted to
    admit an INTERACTIVE one, never the other way round."""
    BATCH = 0
    NORMAL = 1
    INTERACTIVE = 2


class FinishReason:
    """Why a request left the serve plane (string constants, not an enum,
    so responses serialize naturally)."""
    STOP = "stop"              # hit eos_id
    LENGTH = "length"          # max_new_tokens (or ran out of sequence room)
    TIMEOUT = "timeout"        # deadline expired (queued or mid-decode)
    CANCELLED = "cancelled"    # caller cancelled via CompletionHandle.cancel()
    SHED = "shed"              # rejected/evicted at admission (backpressure)
    FAILED = "failed"          # replica failures exhausted the retry budget


@dataclass(frozen=True)
class CompletionRequest:
    """What a caller asks for. ``session_id`` chains multi-turn requests:
    the frontend prepends the session's token history (prior prompts +
    completions), which is exactly the prefix the paged engines' radix
    cache already holds — turn N+1 prefills only its new suffix."""
    prompt: str
    max_new_tokens: int = 16
    deadline_s: Optional[float] = None
    priority: Priority = Priority.NORMAL
    session_id: Optional[str] = None
    sampling: Optional[SamplingParams] = None


@dataclass
class Usage:
    """Per-request accounting, including the real measured cold-start
    time this request waited on (a replica spun up for it), the prompt
    tokens served from the radix prefix cache instead of prefill, and
    how many chunked-prefill passes the prompt took (1 = it fit one
    chunk; more = it amortized across engine steps under the token
    budget)."""
    prompt_tokens: int = 0
    cached_tokens: int = 0
    completion_tokens: int = 0
    cold_start_s: float = 0.0
    prefill_chunks: int = 0
    # lifecycle-span phase durations (from the request's trace span;
    # 0.0 when tracing is off or the phase never happened)
    queue_wait_s: float = 0.0
    decode_s: float = 0.0
    # measured cost attribution (chip-second ledger): this request's
    # share of the engine-step chip-seconds it rode in, priced at
    # USD_PER_CHIP_HOUR; 0.0 when metrics are off or the request was
    # shed before ever sharing a step
    chip_seconds: float = 0.0
    cost_usd: float = 0.0
    # peak KV bytes the request held (dense: its slot's cache share;
    # paged: leased blocks x block nbytes, at quantized width for int8)
    kv_peak_bytes: int = 0
    # speculative decoding: draft proposals the target verified for this
    # request, and how many of them were accepted (committed to the
    # stream). accepted/drafted is the request's acceptance rate; both 0
    # when the engine served it without a draft model
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    # fault containment: times this request was resubmitted onto a
    # healthy replica after its replica failed (deterministic retry —
    # the completion is unaffected; >0 just means it survived a failure)
    retries: int = 0


@dataclass(frozen=True)
class StreamEvent:
    """One streaming increment: ``kind == "token"`` carries a generated
    token id; the terminal ``kind == "done"`` carries the finish reason."""
    kind: str                          # "token" | "done"
    uid: int
    index: int                         # 0-based position in new_tokens
    token: Optional[int] = None
    finish_reason: Optional[str] = None


@dataclass
class CompletionResponse:
    uid: int
    prompt: str
    model: str
    backend: str
    tier: str
    new_tokens: List[int] = field(default_factory=list)
    finish_reason: str = FinishReason.LENGTH
    completed: bool = False            # finished within limits (stop/length)
    ttft_s: float = 0.0
    latency_s: float = 0.0
    usage: Usage = field(default_factory=Usage)
    session_id: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.finish_reason in (FinishReason.STOP, FinishReason.LENGTH)

    @property
    def shed(self) -> bool:
        return self.finish_reason == FinishReason.SHED

    @property
    def cold_start_s(self) -> float:
        """Measured spin-up time attributed to this request (0.0 when it
        was served by an already-live replica)."""
        return self.usage.cold_start_s
