"""Futures-style handles over the serve plane.

``ServeFrontend.submit`` returns a ``CompletionHandle`` immediately —
including for shed requests, whose handle is already resolved with a
structured ``finish_reason == "shed"`` response. The handle is the one
object a caller needs:

  * ``result()``  — drive the serve loop until this request finishes and
                    return its ``CompletionResponse``;
  * ``tokens()``  — incremental streaming iterator: yields one
                    ``StreamEvent`` per generated token as decode
                    iterations land, then a terminal ``done`` event;
  * ``cancel()``  — abort the request wherever it is (admission queue or
                    mid-decode); the engine frees its slot and returns
                    its KV blocks to the pool the same call;
  * ``done()``    — non-blocking completion check.

Handles are single-threaded like the serve plane itself: ``result()``
and ``tokens()`` advance the shared loop via ``frontend.step()``, so
many handles can be interleaved by one driver.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

from repro.api.protocol import (CompletionRequest, CompletionResponse,
                                StreamEvent)


class CompletionHandle:
    def __init__(self, frontend, uid: int, request: CompletionRequest,
                 model: str, backend: str, tier: str):
        self._fe = frontend
        self.uid = uid
        self.request = request
        self.model = model
        self.backend = backend
        self.tier = tier
        self._events: List[StreamEvent] = []
        self.response: Optional[CompletionResponse] = None

    # -- wiring (called by the frontend) ---------------------------------
    def _push_token(self, token: int) -> None:
        self._events.append(StreamEvent(
            kind="token", uid=self.uid, index=len(self._events), token=token))

    def _resolve(self, response: CompletionResponse) -> None:
        self.response = response
        self._events.append(StreamEvent(
            kind="done", uid=self.uid, index=len(self._events),
            finish_reason=response.finish_reason))

    # -- caller surface --------------------------------------------------
    def done(self) -> bool:
        return self.response is not None

    @property
    def shed(self) -> bool:
        return self.response is not None and self.response.shed

    def result(self, max_steps: int = 1_000_000) -> CompletionResponse:
        """Drive the serve loop until this request resolves."""
        steps = 0
        while self.response is None and steps < max_steps:
            if not self._fe.has_work():
                raise RuntimeError(
                    f"request {self.uid} is unresolved but the serve plane "
                    f"is idle — it was never submitted to this frontend")
            self._fe.step()
            steps += 1
        if self.response is None:
            raise RuntimeError(f"request {self.uid} did not finish within "
                               f"{max_steps} serve steps")
        return self.response

    def tokens(self) -> Iterator[StreamEvent]:
        """Incremental stream: yields buffered events, then advances the
        serve loop one decode iteration at a time for more. The token
        events, in order, are exactly the response's ``new_tokens``."""
        i = 0
        while True:
            while i < len(self._events):
                ev = self._events[i]
                i += 1
                yield ev
                if ev.kind == "done":
                    return
            if self.response is None and not self._fe.has_work():
                raise RuntimeError(
                    f"request {self.uid} is unresolved but the serve plane "
                    f"is idle — it was never submitted to this frontend")
            self._fe.step()

    def cancel(self) -> bool:
        """Cancel queued or in-flight work. True if this call cancelled
        the request (its handle resolves with ``finish_reason ==
        "cancelled"`` and the engine's slot + KV blocks are freed);
        False if it had already finished."""
        if self.response is not None:
            return False
        return self._fe.cancel(self.uid)
